"""Shape-adaptive runtime autotuner (paper Fig. 10, beyond the paper).

The paper exposes exactly one tuning parameter — the tile size T — and
Fig. 10 shows L3 throughput is sharply sensitive to it: small tiles
under-saturate device and link (2T^3 flops vs 3T^2 bytes moved), big
tiles starve parallelism (Eq. 2), and the best T depends on the
routine, the problem shape and the device topology.  The repo's
scheduling knobs (``n_streams``, ``policy``) interact with T the same
way.  Instead of one fixed default, the :class:`Autotuner` closes the
loop at runtime:

1. bucket the problem shape (next power of two per dim) so one search
   covers a neighbourhood of shapes;
2. resolve the candidate ``(tile, n_streams, policy)`` configuration
   for the bucket under one of three **modes**:

   * ``"sweep"`` (default) — measure every candidate through
     **metadata-only shadow runs** (``execute=False``) on the
     discrete-event engine (``time_model="events"``) — full
     scheduling/cache/link behaviour, zero numerics, so a sweep costs
     milliseconds even at paper scale — and pick the argmin
     virtual-clock makespan (ties break toward the earlier candidate;
     the default config is always candidate zero, so the tuned pick
     can never be worse than the default under the same cost model);
   * ``"model"`` — predict every candidate's makespan with the
     learned :class:`~repro.tuning.model.CostModel` (ridge regression
     in log space, trained on the rows earlier sweeps left in the
     cache) and **confirm** the predicted winner with measured shadow
     runs of the winner and the default; adopt only when the measured
     winner is ``<= default`` (so the guarantee stays measured, never
     predicted), else fall back to a full sweep;
   * ``"auto"`` — ``"model"`` when the model's residual-based
     prediction interval is tight (``rmse <= max_model_rmse`` on at
     least ``min_model_rows`` training rows), ``"sweep"`` otherwise.
     Cold caches bootstrap through sweeps; once enough evidence has
     accumulated, unseen buckets cost two confirmation runs instead
     of a full sweep (the long-tailed-traffic fix — see
     ``docs/TUNING.md``);

3. persist the winner in the :class:`~repro.tuning.cache.TuningCache`
   keyed by ``topology fingerprint / backend / routine / shape bucket /
   dtype`` — later contexts (and processes, with a file-backed cache)
   start warm and never re-sweep.  Fitted model state persists in the
   same file.

Everything is virtual-clock deterministic: the same topology and shape
always produce the same pick, on any host (model predictions inherit
ordinary float arithmetic, but every adopted makespan is a measured,
deterministic shadow run).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import task as taskmod
from ..core.dtypes import canonical_dtype
from ..core.runtime import BlasxRuntime, RuntimeConfig
from ..core.tiling import ShadowMatrix
from . import model as modelmod
from .cache import TuningCache, resolve_cache

ROUTINES = ("gemm", "syrk", "syr2k", "symm", "trmm", "trsm")
MODES = ("sweep", "model", "auto")

# candidate tile sizes (paper Fig. 10 sweeps 256..4096; 128 covers the
# small-shape end the paper never ran)
DEFAULT_TILE_CANDIDATES = (128, 256, 512, 1024, 2048)
# stream counts worth trying: the paper's 4, the cublasxt-style 2, and
# a deeper pipe for link-bound shapes
DEFAULT_STREAM_CANDIDATES = (2, 4, 8)
# policies worth trying at runtime: the paper's contribution and the
# static speed-proportional split (which wins when stealing/priority
# overhead buys nothing, e.g. perfectly regular single-routine sweeps)
DEFAULT_POLICY_CANDIDATES = ("blasx", "static")
# taskization modes worth trying: owner (Eq. 2) and the Stream-K
# work-centric split (repro.core.task.plan_work_centric) — the latter
# wins on small/ragged shapes where owner DoP underfills the machine
DEFAULT_WORK_CENTRIC_CANDIDATES = (False, True)

# shadow-run budget: skip candidate tiles whose taskization would
# schedule more than this many k-steps (a metadata sweep should stay
# in the milliseconds; the default tile is exempt so the baseline
# makespan always exists)
MAX_SHADOW_STEPS = 60_000
MIN_BUCKET = 64

# model path: only deviate from the default when the predicted win is
# at least this fraction — a hair-thin predicted improvement is inside
# the model's noise, and chasing it risks a confirmation-disproof
# (which costs a full sweep); predicting "the default is fine" costs
# one confirmation run and adopts trivially
MIN_PREDICTED_GAIN = 0.03


def shape_bucket(m: int, k: int, n: int) -> Tuple[int, int, int]:
    """Round each dimension up to the next bucket edge (floor 64): one
    sweep serves every shape in the bucket.

    Edges are powers of two *plus their geometric midpoints*
    ``round(2^p / sqrt(2))``: pure next-power-of-two rounding aliased a
    4100^3 problem into the 8192^3 bucket — nearly 8x the FLOPs — so a
    sweep could crown a tile that loses at the true shape (the ragged
    regime of arXiv 2406.19621).  With the midpoint edge the worst-case
    per-dimension inflation drops from 2x to sqrt(2)x (<= ~2.83x in
    FLOPs for a cubic problem), while buckets stay coarse enough that
    one sweep still serves a neighbourhood of shapes.  Idempotent:
    ``up(up(x)) == up(x)``."""
    def up(x: int) -> int:
        p = 1 << max(0, math.ceil(math.log2(max(1, x))))
        half = round(p / math.sqrt(2))
        return max(MIN_BUCKET, half if x <= half else p)
    return (up(m), up(k), up(n))


def topology_fingerprint(cfg: RuntimeConfig) -> str:
    """Stable hash of the machine-describing config fields (see
    :meth:`RuntimeConfig.topology`)."""
    blob = json.dumps(cfg.topology(), sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def cache_key(fingerprint: str, backend: str, routine: str,
              bucket: Tuple[int, int, int], dtype_name: str) -> str:
    m, k, n = bucket
    return f"{fingerprint}/{backend}/{routine}/{m}x{k}x{n}/{dtype_name}"


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """The autotuner's answer for one (routine, shape bucket, dtype)."""

    tile: int
    n_streams: int
    policy: str
    makespan: float           # winning virtual-clock makespan (seconds)
    default_makespan: float   # the fixed-default config's makespan
    source: str               # "swept" | "model" | "cache" | "cache-file"
    key: str = ""
    work_centric: bool = False  # Stream-K split taskization won

    @property
    def speedup_vs_default(self) -> float:
        return (self.default_makespan / self.makespan
                if self.makespan > 0 else 1.0)


def _shadow_tasks(routine: str, bucket: Tuple[int, int, int], tile: int,
                  dtype) -> Tuple[List, Dict[str, ShadowMatrix], str]:
    """Taskize one routine at bucket scale over shape-only matrices.
    Operand shapes mirror the context-layer calls (side='L', trans='N',
    uplo='U', beta=0 — the tuned knobs dominate the schedule, not the
    variant flags, and one canonical variant keeps sweeps cheap)."""
    m, k, n = bucket
    dt = canonical_dtype(dtype)
    if routine == "gemm":
        mats = {"A": ShadowMatrix("A", m, k, tile, dtype=dt),
                "B": ShadowMatrix("B", k, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_gemm(mats["A"].grid, mats["B"].grid,
                                     mats["C"].grid, "N", "N", 1.0, 0.0)
    elif routine == "syrk":
        mats = {"A": ShadowMatrix("A", n, k, tile, dtype=dt),
                "C": ShadowMatrix("C", n, n, tile, dtype=dt)}
        tasks = taskmod.taskize_syrk(mats["A"].grid, mats["C"].grid,
                                     "U", "N", 1.0, 0.0)
    elif routine == "syr2k":
        mats = {"A": ShadowMatrix("A", n, k, tile, dtype=dt),
                "B": ShadowMatrix("B", n, k, tile, dtype=dt),
                "C": ShadowMatrix("C", n, n, tile, dtype=dt)}
        tasks = taskmod.taskize_syr2k(mats["A"].grid, mats["B"].grid,
                                      mats["C"].grid, "U", "N", 1.0, 0.0)
    elif routine == "symm":
        mats = {"A": ShadowMatrix("A", m, m, tile, dtype=dt),
                "B": ShadowMatrix("B", m, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_symm(mats["A"].grid, mats["B"].grid,
                                     mats["C"].grid, "U", 1.0, 0.0)
    elif routine == "trmm":
        mats = {"A": ShadowMatrix("A", m, m, tile, dtype=dt),
                "Cin": ShadowMatrix("Cin", m, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_trmm(mats["A"].grid, mats["Cin"].grid,
                                     mats["C"].grid, "U", "N", "N", 1.0)
    elif routine == "trsm":
        mats = {"A": ShadowMatrix("A", m, m, tile, dtype=dt),
                "B": ShadowMatrix("B", m, n, tile, dtype=dt),
                "C": ShadowMatrix("C", m, n, tile, dtype=dt)}
        tasks = taskmod.taskize_trsm(mats["A"].grid, mats["B"].grid,
                                     mats["C"].grid, "U", "N", "N", 1.0)
    else:
        raise ValueError(f"unknown routine {routine!r} "
                         f"(expected one of {ROUTINES})")
    return tasks, mats, "C"


class Autotuner:
    """Per-topology configuration search over metadata shadow runs,
    optionally short-circuited by a learned cost model.

    Parameters
    ----------
    cfg:
        The base :class:`RuntimeConfig` — its topology fields define
        the fingerprint; its ``(n_streams, policy)`` plus
        ``default_tile`` form candidate zero (the fixed default every
        sweep is measured against).
    cache:
        ``None`` (process-shared), a path, or a
        :class:`~repro.tuning.cache.TuningCache`.
    mode:
        ``"sweep"`` (exhaustive, the default), ``"model"`` (always
        trust a trained cost model, confirmation-checked), or
        ``"auto"`` (model when its uncertainty is tight, sweep
        otherwise).  See the module docstring.
    tiles / streams / policies:
        Candidate overrides (benchmark lanes restrict these to bound
        sweep cost).
    default_tile:
        The stack-wide fixed default (``repro.api.context.DEFAULT_TILE``
        unless told otherwise).
    min_model_rows / max_model_rmse:
        The ``auto``-mode trust gate: the model must have fit at least
        this many measured rows with a log-residual RMSE at most this
        wide before its predictions replace a sweep.
    """

    def __init__(self, cfg: RuntimeConfig, cache=None, *,
                 mode: str = "sweep",
                 tiles: Sequence[int] = DEFAULT_TILE_CANDIDATES,
                 streams: Sequence[int] = DEFAULT_STREAM_CANDIDATES,
                 policies: Sequence[str] = DEFAULT_POLICY_CANDIDATES,
                 work_centric: Sequence[bool] =
                 DEFAULT_WORK_CENTRIC_CANDIDATES,
                 default_tile: int = 256,
                 min_model_rows: int = modelmod.MIN_ROWS,
                 max_model_rmse: float = modelmod.MAX_RMSE):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.cfg = cfg
        self.cache: TuningCache = resolve_cache(cache)
        self.mode = mode
        self.fingerprint = topology_fingerprint(cfg)
        self.tiles = tuple(tiles)
        self.streams = tuple(streams)
        self.policies = tuple(policies)
        self.work_centric = tuple(bool(w) for w in work_centric)
        self.default_tile = int(default_tile)
        self.min_model_rows = int(min_model_rows)
        self.max_model_rmse = float(max_model_rmse)
        self.sweeps = 0          # shadow runs performed by THIS tuner
        self.bucket_sweeps = 0   # full per-bucket sweeps
        self.confirmations = 0   # model-path confirmation shadow runs
        self.cache_hits = 0      # total (file + process)
        self.file_cache_hits = 0
        self.process_cache_hits = 0
        self.model_adoptions = 0
        self.model_fallbacks = 0  # trained model declined or disproved
        self._events: List[dict] = []   # tuning_report raw material
        self._model: Optional[modelmod.CostModel] = None
        self._model_version = -1        # cache.version the fit saw
        # bootstrap from persisted state so a fresh process predicts
        # before its first in-process sweep (refit on first staleness)
        state = self.cache.model_state()
        if state is not None:
            m = modelmod.CostModel.from_state(state)
            if m.trained:
                self._model = m
                self._model_version = self.cache.version

    # ------------------------------------------------------------ search
    def tune(self, routine: str, m: int, k: Optional[int] = None,
             n: Optional[int] = None, dtype="float64") -> TunedConfig:
        """Return the tuned config for one problem (cache hit, model
        prediction + confirmation, or full sweep — see the class
        docstring for the mode semantics)."""
        k = m if k is None else k
        n = m if n is None else n
        bucket = shape_bucket(m, k, n)
        dt_name = canonical_dtype(dtype).name
        key = cache_key(self.fingerprint, self.cfg.backend, routine,
                        bucket, dt_name)
        entry = self.cache.get(key)
        if entry is not None and entry.get("space") != self._space():
            # the entry was swept against a DIFFERENT default config or
            # candidate space (e.g. a bench lane's restricted tiles):
            # its default_makespan is not this tuner's default and its
            # argmin never saw this tuner's candidates, so the
            # tuned<=default guarantee would silently stop holding.
            # Treat as a miss and re-sweep (the fresh entry overwrites).
            entry = None
        if entry is not None:
            origin = self.cache.origin(key) or "process"
            self.cache_hits += 1
            if origin == "file":
                self.file_cache_hits += 1
            else:
                self.process_cache_hits += 1
            source = "cache-file" if origin == "file" else "cache"
            best = TunedConfig(tile=entry["tile"],
                               n_streams=entry["n_streams"],
                               policy=entry["policy"],
                               makespan=entry["makespan"],
                               default_makespan=entry["default_makespan"],
                               source=source, key=key,
                               work_centric=bool(
                                   entry.get("work_centric", False)))
            self._events.append({"key": key, "source": source,
                                 "swept": 0, **entry})
            return best
        candidates = self._candidates(routine, bucket)
        if self.mode in ("model", "auto"):
            best = self._model_tune(routine, bucket, dt_name, key,
                                    candidates)
            if best is not None:
                return best
        return self._sweep(routine, bucket, dt_name, key, candidates)

    # --------------------------------------------------------- sweep path
    def _sweep(self, routine: str, bucket: Tuple[int, int, int],
               dt_name: str, key: str,
               candidates: List[Tuple[int, int, str, bool]]) -> TunedConfig:
        results = []
        for tile, ns, policy, wc in candidates:
            span = self._shadow_makespan(routine, bucket, tile, dt_name,
                                         ns, policy, wc)
            self.sweeps += 1
            results.append({"tile": tile, "n_streams": ns,
                            "policy": policy, "work_centric": wc,
                            "makespan": span})
        self.bucket_sweeps += 1
        # candidate zero IS the fixed default: the argmin can therefore
        # never be worse than it (the acceptance invariant)
        default_span = results[0]["makespan"]
        best_row = min(results, key=lambda r: r["makespan"])
        entry = self._entry(routine, bucket, dt_name, best_row,
                            default_span, results)
        self.cache.put(key, entry)
        self._events.append({"key": key, "source": "swept",
                             "swept": len(results), **entry})
        return TunedConfig(tile=best_row["tile"],
                           n_streams=best_row["n_streams"],
                           policy=best_row["policy"],
                           makespan=best_row["makespan"],
                           default_makespan=default_span,
                           source="swept", key=key,
                           work_centric=best_row["work_centric"])

    # --------------------------------------------------------- model path
    def _ensure_model(self) -> Optional[modelmod.CostModel]:
        """The cost model fitted against the cache's current rows
        (refit whenever the cache version moved); persisted back into
        the cache so file-backed caches carry their model with them."""
        if self._model is not None and \
                self._model_version == self.cache.version:
            return self._model
        rows = modelmod.training_rows(self.cache, self.fingerprint,
                                      self.cfg.backend,
                                      self.cfg.topology())
        model = modelmod.CostModel().fit(rows)
        self._model = model if model.trained else None
        self._model_version = self.cache.version
        if model.trained:
            self.cache.set_model_state(model.state())
        return self._model

    def _model_tune(self, routine: str, bucket: Tuple[int, int, int],
                    dt_name: str, key: str,
                    candidates: List[Tuple[int, int, str]]
                    ) -> Optional[TunedConfig]:
        """Predict per-candidate makespans, confirm the predicted
        winner against the measured default, adopt on success.  Returns
        ``None`` to fall back to the sweep (cold/untrusted model, or
        the confirmation disproved the prediction)."""
        model = self._ensure_model()
        if model is None:
            # nothing to learn from yet: bootstrap through a sweep
            # (whose rows become the training set)
            self.model_fallbacks += 1
            self._events.append({"key": key, "source": "model-fallback",
                                 "reason": "untrained"})
            return None
        trusted = (model.n_rows >= self.min_model_rows
                   and model.rmse <= self.max_model_rmse)
        if self.mode == "auto" and not trusted:
            self.model_fallbacks += 1
            self._events.append({
                "key": key, "source": "model-fallback",
                "reason": "untrusted",
                "model_rmse": model.rmse, "model_rows": model.n_rows})
            return None
        topo = self.cfg.topology()
        preds = [model.predict(modelmod.features(
            routine, bucket, dt_name, topo, tile, ns, policy,
            work_centric=wc))
            for tile, ns, policy, wc in candidates]
        win_idx = min(range(len(preds)), key=preds.__getitem__)
        if preds[win_idx] >= preds[0] * (1 - MIN_PREDICTED_GAIN):
            win_idx = 0          # predicted win is inside model noise
        winner, default = candidates[win_idx], candidates[0]
        # single confirmation run of the predicted winner; the measured
        # default is the other half of the tuned<=default guarantee
        # (free when the model already picked the default itself)
        win_span = self._shadow_makespan(routine, bucket, winner[0],
                                         dt_name, winner[1], winner[2],
                                         winner[3])
        self.sweeps += 1
        self.confirmations += 1
        if winner == default:
            default_span = win_span
            measured = [{"tile": winner[0], "n_streams": winner[1],
                         "policy": winner[2], "work_centric": winner[3],
                         "makespan": win_span}]
        else:
            default_span = self._shadow_makespan(
                routine, bucket, default[0], dt_name, default[1],
                default[2], default[3])
            self.sweeps += 1
            self.confirmations += 1
            measured = [
                {"tile": default[0], "n_streams": default[1],
                 "policy": default[2], "work_centric": default[3],
                 "makespan": default_span},
                {"tile": winner[0], "n_streams": winner[1],
                 "policy": winner[2], "work_centric": winner[3],
                 "makespan": win_span},
            ]
        if win_span > default_span * (1 + 1e-12):
            # prediction disproved by measurement: the guarantee is
            # measured, so fall back to the full sweep (whose rows also
            # enrich the training set exactly where the model was wrong)
            self.model_fallbacks += 1
            self._events.append({
                "key": key, "source": "model-fallback",
                "reason": "confirmation",
                "predicted_makespan": preds[win_idx],
                "measured_makespan": win_span,
                "default_makespan": default_span})
            return None
        best_row = {"tile": winner[0], "n_streams": winner[1],
                    "policy": winner[2], "work_centric": winner[3],
                    "makespan": win_span}
        # only MEASURED rows enter "candidates" (the training set);
        # predictions ride along separately for introspection
        entry = self._entry(routine, bucket, dt_name, best_row,
                            default_span, measured)
        entry["predicted"] = {
            "winner_makespan": preds[win_idx],
            "default_makespan": preds[0],
            "model_rmse": model.rmse, "model_rows": model.n_rows,
        }
        self.cache.put(key, entry)
        self.model_adoptions += 1
        self._events.append({"key": key, "source": "model",
                             "swept": len(measured), **entry})
        return TunedConfig(tile=winner[0], n_streams=winner[1],
                           policy=winner[2], makespan=win_span,
                           default_makespan=default_span,
                           source="model", key=key,
                           work_centric=winner[3])

    # ------------------------------------------------------------ helpers
    def _entry(self, routine: str, bucket: Tuple[int, int, int],
               dt_name: str, best_row: dict, default_span: float,
               measured: List[dict]) -> dict:
        return {
            "routine": routine, "bucket": list(bucket), "dtype": dt_name,
            "tile": best_row["tile"], "n_streams": best_row["n_streams"],
            "policy": best_row["policy"],
            "work_centric": best_row.get("work_centric", False),
            "makespan": best_row["makespan"],
            "default_makespan": default_span,
            "candidates": measured,
            "space": self._space(),
            "topology": self.cfg.topology(),
        }

    def _space(self) -> dict:
        """What a cached entry's verdict depends on besides the key:
        the default config it was measured against and the candidate
        space its argmin saw.  Hits require an exact match — a tuner
        with a different default tile / streams / policy or a wider
        candidate set must re-sweep, or 'tuned never worse than
        default' would quietly refer to someone else's default."""
        return {
            "default": [self.default_tile, self.cfg.n_streams,
                        self.cfg.policy, bool(self.cfg.work_centric)],
            "tiles": list(self.tiles),
            "streams": list(self.streams),
            "policies": list(self.policies),
            "work_centric": list(self.work_centric),
        }

    def _candidates(self, routine: str,
                    bucket: Tuple[int, int, int]
                    ) -> List[Tuple[int, int, str, bool]]:
        """Ordered candidate list; the fixed default config comes first
        and is never budget-filtered."""
        m, k, n = bucket
        default = (self.default_tile, self.cfg.n_streams, self.cfg.policy,
                   bool(self.cfg.work_centric))
        out = [default]
        capacity = self.cfg.n_devices * self.cfg.effective_streams
        for tile in self.tiles:
            if tile > max(m, k, n):
                continue            # degenerate: one tile holds everything
            for wc in self.work_centric:
                if self._step_estimate(routine, bucket, tile,
                                       work_centric=wc,
                                       capacity=capacity) > MAX_SHADOW_STEPS:
                    continue        # sweep budget: skip pathological grids
                for ns in self.streams:
                    for policy in self.policies:
                        cand = (tile, ns, policy, bool(wc))
                        if cand != default and cand not in out:
                            out.append(cand)
        return out

    @staticmethod
    def _step_estimate(routine: str, bucket: Tuple[int, int, int],
                       tile: int, work_centric: bool = False,
                       capacity: int = 8) -> int:
        """Scheduled k-step count of one candidate taskization — the
        sweep-budget yardstick and (mirrored in ``repro.tuning.model``)
        the cost model's ``lsteps`` feature.  Under the work-centric
        mode every split tile re-walks its k-loop once more (the
        partials' slices plus the fix-up's full re-dispatch), mirroring
        :func:`repro.core.tiling.workcentric_parts`: all tiles split on
        small problems (owner count below ``capacity``), only ragged
        boundary tiles split on large ones."""
        m, k, n = bucket
        rows = math.ceil(m / tile)
        cols = math.ceil(n / tile)
        depth = math.ceil(k / tile)
        factor = 1
        if routine in ("syrk", "syr2k"):
            rows = cols = math.ceil(n / tile)
            ntasks = rows * (rows + 1) // 2
            factor = 2 if routine == "syr2k" else 1
            interior = (n // tile) * ((n // tile) + 1) // 2
        else:
            if routine in ("symm", "trmm", "trsm"):
                depth = math.ceil(m / tile)
            ntasks = rows * cols
            interior = (m // tile) * (n // tile)
        base = ntasks * depth * factor
        if not work_centric or depth * factor < 2:
            return base
        split = ntasks if ntasks < capacity else max(0, ntasks - interior)
        return base + split * depth * factor

    def _shadow_makespan(self, routine: str, bucket: Tuple[int, int, int],
                         tile: int, dtype: str, n_streams: int,
                         policy: str, work_centric: bool = False) -> float:
        """One metadata-only run of (routine, bucket) under a candidate
        config; returns the virtual-clock makespan."""
        cfg = dataclasses.replace(
            self.cfg, mode="sim", time_model="events", execute=False,
            record_trace=False, n_streams=n_streams, rs_slots=None,
            policy=policy, work_centric=work_centric)
        tasks, mats, out_id = _shadow_tasks(routine, bucket, tile, dtype)
        rt = BlasxRuntime(cfg)
        rt.run(tasks, mats, out_id)
        return rt.makespan()

    # ------------------------------------------------------------- report
    def report(self) -> dict:
        """Introspection surface behind ``ctx.tuning_report()``."""
        model = self._model
        return {
            "mode": self.mode,
            "fingerprint": self.fingerprint,
            "backend": self.cfg.backend,
            "cache_path": self.cache.path,
            "cache_entries": len(self.cache),
            "sweeps": self.sweeps,
            "bucket_sweeps": self.bucket_sweeps,
            "confirmations": self.confirmations,
            "cache_hits": self.cache_hits,
            "file_cache_hits": self.file_cache_hits,
            "process_cache_hits": self.process_cache_hits,
            "model_adoptions": self.model_adoptions,
            "model_fallbacks": self.model_fallbacks,
            "model": ({"trained": True, "n_rows": model.n_rows,
                       "rmse": model.rmse}
                      if model is not None and model.trained
                      else {"trained": False, "n_rows": 0,
                            "rmse": None}),
            "tile_candidates": list(self.tiles),
            "stream_candidates": list(self.streams),
            "policy_candidates": list(self.policies),
            "work_centric_candidates": list(self.work_centric),
            "entries": [dict(e) for e in self._events],
        }
