"""AdamW with ZeRO-1 sharded states, cosine schedule, global-norm clip,
and optional int8 error-feedback gradient compression for the DP
all-reduce (a distributed-optimization trick for the 1000+ node story;
see DESIGN.md §6).

Pure JAX, pytree-native — no optax dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params: Any) -> dict:
    """m/v in f32.  Under pjit these inherit the (fully sharded) param
    shardings — ZeRO-1 falls out of GSPMD when param specs shard both
    mesh axes (see models/sharding.py)."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(params: Any) -> dict:
    """ShapeDtypeStruct twin of init_state for the dry-run."""
    def sds(p):
        sh = getattr(p, "sharding", None)
        if sh is not None and not isinstance(sh, jax.sharding.SingleDeviceSharding):
            return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=sh)
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(sds, params),
        "v": jax.tree.map(sds, params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree, jnp.float32(0.0)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                  ) -> Tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


# ----------------------------------------------------- int8 moment states
# Blockwise (128-element) int8 quantization of AdamW's m/v moments —
# the 8-bit-optimizer trick that shrinks state from 8 to ~2.06 bytes
# per parameter.  This is what lets DeepSeek-V3-scale training fit the
# 512-chip mesh (see EXPERIMENTS.md §Dry-run): bf16 params 2.6 GB/chip
# + int8 moments 2.8 GB/chip vs 21 GB/chip for f32 moments.
QBLOCK = 128


def quantize_blockwise(x: jax.Array):
    """f32 -> (int8 payload, f32 per-block scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blockwise(q: jax.Array, scale: jax.Array, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def init_state_int8(params: Any) -> dict:
    def zeros_q(p):
        n = max(1, -(-p.size // QBLOCK))
        return {"q": jnp.zeros((n, QBLOCK), jnp.int8),
                "scale": jnp.zeros((n,), jnp.float32)}
    return {
        "m": jax.tree.map(zeros_q, params),
        "v": jax.tree.map(zeros_q, params),
        "step": jnp.zeros((), jnp.int32),
        "int8": True,
    }


def apply_updates_int8(cfg: AdamWConfig, params: Any, grads: Any,
                       state: dict) -> Tuple[Any, dict, dict]:
    """AdamW with int8 moments: dequantize -> update -> requantize."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mq, vq):
        gf = g.astype(jnp.float32)
        m = dequantize_blockwise(mq["q"], mq["scale"], p.shape)
        # v is stored as sqrt(v): halves its dynamic range so blockwise
        # linear int8 holds it without zero-flushing small entries
        v = dequantize_blockwise(vq["q"], vq["scale"], p.shape) ** 2
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        delta = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        q_m, s_m = quantize_blockwise(m2)
        q_v, s_v = quantize_blockwise(jnp.sqrt(v2))
        return p2, {"q": q_m, "scale": s_m}, {"q": q_v, "scale": s_v}

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    is_q = lambda t: isinstance(t, dict) and "q" in t
    flat_m = jax.tree_util.tree_structure(params).flatten_up_to(state["m"])
    flat_v = jax.tree_util.tree_structure(params).flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step, "int8": True}, \
        {"grad_norm": gnorm, "lr": lr}


# ------------------------------------------------------------ compression
def compress_int8(g: jax.Array, err: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback int8 quantization: returns (q, scale, new_err).
    The residual (g + err - dequant(q)) is carried to the next step, so
    compression bias vanishes in expectation."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Any, err_state: Any, axis_name: str
                    ) -> Tuple[Any, Any]:
    """DP all-reduce with int8 payload + error feedback (for use inside
    shard_map training steps when cross-pod bandwidth is the binder)."""
    def one(g, e):
        q, scale, new_e = compress_int8(g, e)
        summed = jax.lax.psum(decompress_int8(q, scale), axis_name)
        n = jax.lax.psum(1, axis_name)  # static; axis_size needs newer jax
        return summed / n, new_e
    pairs = jax.tree.map(one, grads, err_state)
    g2 = jax.tree.map(lambda t: t[0], pairs,
                      is_leaf=lambda t: isinstance(t, tuple))
    e2 = jax.tree.map(lambda t: t[1], pairs,
                      is_leaf=lambda t: isinstance(t, tuple))
    return g2, e2
