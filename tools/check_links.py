#!/usr/bin/env python3
"""Markdown link checker for README + docs/ (the CI lint-job step).

Checks every ``[text](target)`` link in the given markdown files
(default: ``README.md`` plus ``docs/*.md`` at the repo root):

* **internal file links** (``docs/TUNING.md``, ``../ROADMAP.md``) —
  the target must exist relative to the linking file: hard failure;
* **internal anchors** (``#shape-buckets``, ``TUNING.md#cache-file-
  layout``) — the target file must contain a heading whose
  GitHub-style slug matches: hard failure;
* **external links** (``http(s)://…``) — advisory only: listed, never
  fetched (CI runners have no business failing on a flaky remote).

Links inside fenced code blocks and inline code spans are ignored.
Exit status is non-zero iff any hard check failed.

Usage::

    python tools/check_links.py [FILE.md ...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import Dict, List, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent

FENCE_RE = re.compile(r"^(```|~~~)")
CODESPAN_RE = re.compile(r"`[^`]*`")
# [text](target) — target ends at the first unescaped ')'; images too
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


def strip_code(lines: List[str]) -> List[str]:
    """Blank out fenced blocks and inline code spans, keep line count."""
    out: List[str] = []
    in_fence = False
    for line in lines:
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else CODESPAN_RE.sub("", line))
    return out


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    text = CODESPAN_RE.sub(lambda m: m.group(0)[1:-1], heading)
    # markdown emphasis/links don't survive into the anchor text
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: Path, cache: Dict[Path, set]) -> set:
    if path not in cache:
        slugs: Dict[str, int] = {}
        found = set()
        in_fence = False
        for line in path.read_text(encoding="utf-8").splitlines():
            if FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = github_slug(m.group(2))
            n = slugs.get(slug, 0)
            slugs[slug] = n + 1
            found.add(slug if n == 0 else f"{slug}-{n}")
        cache[path] = found
    return cache[path]


def check_file(md: Path, anchor_cache: Dict[Path, set]
               ) -> Tuple[List[str], List[str]]:
    """Return (hard_failures, external_links) for one markdown file."""
    failures: List[str] = []
    external: List[str] = []
    lines = strip_code(md.read_text(encoding="utf-8").splitlines())
    for lineno, line in enumerate(lines, 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            where = f"{_rel(md)}:{lineno}"
            if target.startswith(("http://", "https://")):
                external.append(f"{where}: {target}")
                continue
            if target.startswith("mailto:"):
                continue
            path_part, _, frag = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
                if not dest.exists():
                    failures.append(f"{where}: broken link -> {target}"
                                    f" (no such file {path_part})")
                    continue
            else:
                dest = md
            if frag:
                if dest.suffix.lower() not in (".md", ".markdown"):
                    continue
                if frag.lower() not in anchors_of(dest, anchor_cache):
                    failures.append(f"{where}: broken anchor -> {target}"
                                    f" (no heading slugs to '{frag}' in "
                                    f"{_rel(dest)})")
    return failures, external


def default_files() -> List[Path]:
    files = [REPO_ROOT / "README.md"]
    files += sorted((REPO_ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def main(argv: List[str] | None = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    files = [Path(a).resolve() for a in args] if args else default_files()
    anchor_cache: Dict[Path, set] = {}
    all_failures: List[str] = []
    n_external = 0
    for md in files:
        if not md.exists():
            all_failures.append(f"{md}: file does not exist")
            continue
        failures, external = check_file(md, anchor_cache)
        all_failures += failures
        n_external += len(external)
        for ext in external:
            print(f"advisory: external link (not fetched): {ext}")
    for f in all_failures:
        print(f"FAIL: {f}")
    print(f"check_links: {len(files)} files, {n_external} external links "
          f"(advisory), {len(all_failures)} hard failures")
    return 1 if all_failures else 0


if __name__ == "__main__":
    sys.exit(main())
