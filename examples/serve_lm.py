"""Batched serving example: continuous-batching inference with
demand-driven slot admission (the BLASX scheduling insight applied to
request scheduling — free slots pull work, no head-of-line blocking).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import ServeConfig, run


def main():
    out = run(ServeConfig(
        arch="olmo_1b", smoke=True,
        batch_slots=4, prompt_len=12, max_len=48,
        requests=10, max_new=12,
    ))
    print(f"served {out['requests']} requests / {out['tokens']} tokens "
          f"in {out['wall_s']:.2f}s -> {out['tok_per_s']:.1f} tok/s "
          f"({out['steps']} batched decode steps)")
    for rid, toks in sorted(out["outputs"].items())[:3]:
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
