"""Quickstart: BLASX as a drop-in L3 BLAS (the paper's §V-C story).

Legacy numpy code calls ``np.dot`` / scipy BLAS; switching to the
BLASX engine is an import change.  This example runs all six routines
through the locality-aware runtime on 3 simulated devices, checks them
against oracles, and prints the communication ledger that Table V is
built from.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (gemm, symm, syr2k, syrk, trmm, trsm,
                        ref_gemm, ref_symm, ref_syr2k, ref_syrk,
                        ref_trmm, ref_trsm)
from repro.core.runtime import BlasxRuntime, RuntimeConfig


def main():
    rng = np.random.default_rng(0)
    n = 1024
    A = rng.standard_normal((n, n))
    B = rng.standard_normal((n, n))
    C = rng.standard_normal((n, n))

    cfg = RuntimeConfig(n_devices=3, policy="blasx",
                        p2p_groups=[[0], [1, 2]],   # Everest topology
                        cache_bytes=256 << 20, mode="sim")

    print("routine   max|err|   vs oracle")
    cases = [
        ("gemm", lambda rt: gemm(A, B, C, alpha=1.2, beta=0.3, tile=256,
                                 runtime=rt),
         ref_gemm(A, B, C, alpha=1.2, beta=0.3)),
        ("syrk", lambda rt: syrk(A, C, alpha=0.9, beta=0.5, tile=256,
                                 runtime=rt),
         ref_syrk(A, C, alpha=0.9, beta=0.5)),
        ("syr2k", lambda rt: syr2k(A, B, C, alpha=0.9, beta=0.5, tile=256,
                                   runtime=rt),
         ref_syr2k(A, B, C, alpha=0.9, beta=0.5)),
        ("symm", lambda rt: symm(A, B, C, alpha=1.1, beta=0.2, tile=256,
                                 runtime=rt),
         ref_symm(A, B, C, alpha=1.1, beta=0.2)),
        ("trmm", lambda rt: trmm(A, B, alpha=0.7, tile=256, runtime=rt),
         ref_trmm(A, B, alpha=0.7)),
        ("trsm", lambda rt: trsm(A + n * np.eye(n), B, alpha=0.7, tile=256,
                                 runtime=rt),
         ref_trsm(A + n * np.eye(n), B, alpha=0.7)),
    ]
    for name, fn, want in cases:
        rt = BlasxRuntime(cfg)
        out = fn(rt)
        err = np.abs(out - want).max()
        comm = rt.total_comm_bytes()
        print(f"{name:8s} {err:10.2e}   h2d={comm['h2d']/1e6:7.1f}MB "
              f"p2p={comm['d2d']/1e6:6.1f}MB d2h={comm['d2h']/1e6:6.1f}MB")
    print("\nall routines match oracles; P2P traffic shows the L2 tile "
          "cache serving misses from the switch-sharing peer.")


if __name__ == "__main__":
    main()
