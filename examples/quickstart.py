"""Quickstart: the two-layer BLASX API (the paper's §V-C story).

High-level layer — a persistent ``BlasxContext`` runs all six L3
routines on 3 simulated devices with warm ALRU/MESI-X tile caches:
operands registered once (``ctx.tile``) are fetched once, and every
later routine that touches them is served from cache (watch the
per-call H2D column fall).  Low-level layer — the same engine behind
strict CBLAS signatures for legacy callers.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import (BlasxContext, CblasNoTrans, CblasRowMajor,
                       cblas_dgemm)
from repro.core import (ref_gemm, ref_symm, ref_syr2k, ref_syrk, ref_trmm,
                        ref_trsm)
from repro.core.runtime import RuntimeConfig


def main():
    rng = np.random.default_rng(0)
    n = 1024
    A = rng.standard_normal((n, n))
    B = rng.standard_normal((n, n))
    C = rng.standard_normal((n, n))
    T = A + n * np.eye(n)                      # well-conditioned triangular

    cfg = RuntimeConfig(n_devices=3, policy="blasx",
                        p2p_groups=[[0], [1, 2]],   # Everest topology
                        cache_bytes=256 << 20, mode="sim")

    with BlasxContext(cfg, tile=256) as ctx:
        # register once — every routine below reuses these cached tiles
        Ah, Bh, Th = ctx.tile(A), ctx.tile(B), ctx.tile(T)

        cases = [
            ("gemm", lambda: ctx.gemm(Ah, Bh, C, alpha=1.2, beta=0.3),
             ref_gemm(A, B, C, alpha=1.2, beta=0.3)),
            ("syrk", lambda: ctx.syrk(Ah, C, alpha=0.9, beta=0.5),
             ref_syrk(A, C, alpha=0.9, beta=0.5)),
            ("syr2k", lambda: ctx.syr2k(Ah, Bh, C, alpha=0.9, beta=0.5),
             ref_syr2k(A, B, C, alpha=0.9, beta=0.5)),
            ("symm", lambda: ctx.symm(Ah, Bh, C, alpha=1.1, beta=0.2),
             ref_symm(A, B, C, alpha=1.1, beta=0.2)),
            ("trmm", lambda: ctx.trmm(Ah, Bh, alpha=0.7),
             ref_trmm(A, B, alpha=0.7)),
            ("trsm", lambda: ctx.trsm(Th, Bh, alpha=0.7),
             ref_trsm(T, B, alpha=0.7)),
        ]
        print("routine   max|err|   per-call ledger (warm context)")
        for name, fn, want in cases:
            out = fn()
            err = np.abs(out.array() - want).max()
            c = ctx.last_call
            print(f"{name:8s} {err:10.2e}   h2d={c.h2d_bytes/1e6:7.1f}MB "
                  f"p2p={c.d2d_bytes/1e6:6.1f}MB "
                  f"d2h={c.d2h_bytes/1e6:6.1f}MB  l1_hits={c.l1_hits}")

        # async serving-shaped traffic: submissions overlap the host,
        # shared weights (Bh) stay cached across the whole batch
        futs = [ctx.submit("gemm", ctx.tile(x), Bh)
                for x in (rng.standard_normal((256, n)) for _ in range(4))]
        warm = [f.result() for f in futs]
        print(f"\nasync batch: {len(warm)} gemms, last-call h2d="
              f"{ctx.last_call.h2d_bytes/1e6:.1f}MB (weights served "
              "from the warm L1/L2 tile caches)")

        st = ctx.stats()
        print(f"session: {st['calls']} calls, "
              f"h2d={st['comm_bytes']['h2d']/1e9:.2f}GB "
              f"p2p={st['comm_bytes']['d2d']/1e9:.2f}GB")

    # ---- legacy layer: strict CBLAS signatures, in-place C update ----
    Cb = np.array(C, copy=True)
    cblas_dgemm(CblasRowMajor, CblasNoTrans, CblasNoTrans, n, n, n,
                1.2, A, n, B, n, 0.3, Cb, n)
    err = np.abs(Cb - ref_gemm(A, B, C, alpha=1.2, beta=0.3)).max()
    print(f"\ncblas_dgemm max|err| = {err:.2e} (legacy layer, same engine)")


if __name__ == "__main__":
    main()
