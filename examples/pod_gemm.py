"""The paper's workload on the TPU mesh: out-of-core distributed GEMM
with the BLASX ring schedule (L2-cache/overlap insight on ICI).

Spawns with 8 host devices (this example re-execs itself with XLA_FLAGS
if needed) and compares the ring collective-matmul against the plain
GSPMD lowering: same numerics, collective-permute (neighbor) traffic
instead of monolithic all-gathers.

Run:  PYTHONPATH=src python examples/pod_gemm.py
"""
import os
import sys

if "--respawned" not in sys.argv and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
    os.execv(sys.executable, [sys.executable] + sys.argv + ["--respawned"])

import jax                      # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.api import BlasxContext  # noqa: E402
from repro.core import distributed as dist  # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((512, 1024)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((1024, 768)), jnp.float32)
    # host-side oracle through the persistent-context API (the tiled
    # engine whose L2/overlap insight the ring schedule ports to ICI)
    with BlasxContext(tile=256) as ctx:
        want = np.array(ctx.gemm(np.asarray(A), np.asarray(B)).array(),
                        dtype=np.float32)

    for mode in ("gspmd", "ring"):
        f = jax.jit(lambda a, b, m=mode: dist.distributed_gemm(
            a, b, mesh, mode=m))
        compiled = f.lower(A, B).compile()
        out = compiled(A, B)
        err = np.abs(np.asarray(out) - want).max()
        txt = compiled.as_text()
        print(f"{mode:6s} max|err|={err:.2e} "
              f"all-gathers={txt.count('all-gather(')} "
              f"collective-permutes={txt.count('collective-permute')}")
    print("\nring mode: panels circulate the ICI ring (neighbor P2P, the "
          "paper's L2 tile cache) with the next hop issued before each "
          "matmul (the paper's stream overlap).")


if __name__ == "__main__":
    main()
