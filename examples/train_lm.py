"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on CPU with checkpointing, resume, and metrics.

This is the deliverable-(b) end-to-end example: real data pipeline ->
model -> AdamW -> checkpoint, through the same launch stack the pod
uses.  (The reduced() smoke config is ~1M params; here we build a
mid-size config so the loss curve is meaningful but CPU-feasible.)

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch.train import TrainConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    out = run(TrainConfig(
        arch="qwen3_0_6b",
        smoke=True,               # reduced config; raise dims for ~100M
        steps=args.steps,
        seq_len=128,
        global_batch=8,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        log_every=20,
        lr=1e-3,
    ))
    print(f"\nloss: {out['first_loss']:.4f} -> {out['last_loss']:.4f} "
          f"({out['final_step']} steps, last ckpt @ {out['last_ckpt']})")
    assert out["last_loss"] < out["first_loss"], "training must learn"


if __name__ == "__main__":
    main()
